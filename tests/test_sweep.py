"""Runtime-parameterized sweeps: `truncate_sweep` must reproduce `truncate`
exactly from format tables, evaluate whole candidate ladders through ONE
compiled executable (no per-candidate retrace/recompile), and the batched
`autosearch` must stay within an O(1) XLA-compilation budget."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro import search
from repro.core import (
    truncate, truncate_sweep, TruncationPolicy, TruncationRule, scope,
)
from repro.core import policy as policy_mod
from repro.core.policy import magnitude_below

try:
    from jax._src import test_util as _jtu
    _count_compiles = _jtu.count_jit_compilation_cache_miss
except (ImportError, AttributeError):  # jax moved the helper
    _count_compiles = None

needs_compile_counter = pytest.mark.skipif(
    _count_compiles is None, reason="no jax compile-cache counter available")


def _toy(w1, w2, x):
    with scope("attn"):
        h = jnp.tanh(x @ w1)
    with scope("mlp"):
        def body(c, _):
            return jax.nn.relu(c @ w2), None
        h, _ = lax.scan(body, h, None, length=3)
    with scope("head"):
        return jnp.mean(h * h)


def _toy_args(seed=0):
    r = np.random.RandomState(seed)
    return (jnp.asarray(r.randn(32, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(64, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(16, 32), jnp.float32))


_POLICIES = [
    TruncationPolicy.everywhere("e5m2"),
    TruncationPolicy.scoped("mlp", "e8m7"),
    TruncationPolicy.scoped("attn", "e4m3"),
    TruncationPolicy.everywhere("e5m7").excluding("mlp"),
    TruncationPolicy(rules=(TruncationRule(fmt="e8m3", scope="attn"),
                            TruncationRule(fmt="e5m2", scope="head"))),
    TruncationPolicy(rules=()),
]


def test_table_eval_matches_truncate_exactly():
    """Any policy within the site set, lowered to a table, must produce the
    same bits as the static per-policy transform (incl. scan bodies and
    excludes)."""
    args = _toy_args()
    handle = truncate_sweep(_toy, TruncationPolicy.everywhere("e5m2"))(*args)
    assert handle.num_sites >= 4
    for pol in _POLICIES:
        a = float(truncate(_toy, pol)(*args))
        b = float(handle(handle.table(pol)))
        assert a == b, pol


def test_batch_matches_single_rows():
    args = _toy_args()
    handle = truncate_sweep(_toy, TruncationPolicy.everywhere("e5m2"))(*args)
    tables = handle.tables(_POLICIES)
    outs = handle.batch(tables)
    for i in range(len(_POLICIES)):
        assert float(outs[i]) == float(handle(tables[i]))


def test_sweep_walks_jaxpr_once():
    args = _toy_args()
    sw = truncate_sweep(_toy, TruncationPolicy.everywhere("e5m2"))
    h1 = sw(*args)
    for pol in _POLICIES:
        h1(h1.table(pol))
    h2 = sw(*args)  # same signature -> cached sites/executable
    h2.batch(h2.tables(_POLICIES))
    assert sw.n_traces == 1
    assert sw.cache_size() == 1
    # a new input signature is a new walk, exactly one
    sw(*_toy_args()[:2], _toy_args()[2][:8])
    assert sw.n_traces == 2


def test_shared_subjaxpr_sites_are_per_call_site():
    """jax's tracing cache shares one ClosedJaxpr object between call sites
    of the same jitted helper; each call site's scope must still get its own
    quantize sites (regression: id()-keyed enumeration let the first call
    site's rows shadow every other, so scoped policies quantized the wrong
    scope)."""
    helper = jax.jit(lambda v: jnp.sin(v) * 1.5)

    def f(x):
        with scope("a"):
            y = helper(x)
        with scope("b"):
            z = helper(x + 1.0)
        return jnp.sum(y) + jnp.sum(z)

    x = jnp.asarray(np.random.RandomState(0).randn(16, 8), jnp.float32)
    handle = truncate_sweep(f, TruncationPolicy.everywhere("e5m2"))(x)
    for pol in (TruncationPolicy.scoped("a", "e5m2"),
                TruncationPolicy.scoped("b", "e5m2"),
                TruncationPolicy.everywhere("e5m2")):
        assert float(handle(handle.table(pol))) == float(truncate(f, pol)(x)), pol
    # and the two scoped policies genuinely differ from full precision
    full = float(f(x))
    assert float(handle(handle.table(TruncationPolicy.scoped("a", "e5m2")))) != full
    assert float(handle(handle.table(TruncationPolicy.scoped("b", "e5m2")))) != full


def test_closure_captured_tracer_rejected_not_cached():
    """A closure that captures a value from an enclosing trace must raise —
    and must NOT poison the signature cache for later concrete calls
    (regression: the entry was cached and every subsequent call died with
    UnexpectedTracerError)."""
    args = _toy_args()
    site_pol = TruncationPolicy.everywhere("e5m2")
    sw = truncate_sweep(_toy, site_pol)

    def inside(t):
        # tracer in the input leaves
        with pytest.raises(TypeError):
            sw(args[0] * t, args[1], args[2])
        # concrete leaves, but the traced fn closes over the tracer
        scaled = lambda w1, w2, x: _toy(w1 * t, w2, x)
        with pytest.raises(TypeError):
            truncate_sweep(scaled, site_pol)(*args)
        return t

    jax.jit(inside)(jnp.float32(1.0))
    assert sw.cache_size() == 0
    handle = sw(*args)  # same signature, now concrete: must work
    assert float(handle(handle.identity_table())) == float(_toy(*args))


def test_site_policy_rejects_runtime_unrepresentable_rules():
    args = _toy_args()
    masked = TruncationPolicy(rules=(
        TruncationRule(fmt="e5m2", mask=magnitude_below(1.0)),))
    with pytest.raises(ValueError):
        truncate_sweep(_toy, masked)(*args)
    handle = truncate_sweep(_toy, TruncationPolicy.everywhere("e5m2"))(*args)
    with pytest.raises(ValueError):
        handle.table(masked)


@needs_compile_counter
def test_policy_ladder_single_compile():
    """The tentpole guarantee at executable level: N candidate policies
    through one sweep handle cost ONE XLA compilation (static `truncate`
    would cost N)."""
    args = _toy_args()
    handle = truncate_sweep(_toy, TruncationPolicy.everywhere("e5m2"))(*args)
    tables = handle.tables(_POLICIES)
    with _count_compiles() as n:
        for i in range(len(_POLICIES)):
            handle(tables[i])
    assert n[0] == 1, f"per-candidate recompile detected ({n[0]} compiles)"
    with _count_compiles() as n:
        handle.batch(tables)
        handle.batch(handle.tables(_POLICIES[::-1]))  # same K -> same exe
    assert n[0] == 1, f"batched sweep recompiled ({n[0]} compiles)"


@needs_compile_counter
def test_autosearch_compile_budget_toy():
    """CI compile-count regression: the batched search must not recompile
    per candidate — one batched executable serves the whole run."""
    args = _toy_args()
    with _count_compiles() as n:
        res = search.autosearch(_toy, args, search.rel_error, 32,
                                threshold=1e-2)
    assert res.converged
    assert res.evals_used > 2  # plenty of candidates were actually evaluated
    assert n[0] <= 2, f"search compiled {n[0]} executables"
    assert res.n_compiles <= 2


@needs_compile_counter
@pytest.mark.slow
def test_autosearch_compile_budget_bench_model():
    """Acceptance: autosearch on benchmarks.common.bench_model performs at
    most 2 XLA compilations total (down from O(scopes × widths))."""
    from benchmarks.common import bench_model, bench_batch

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    with _count_compiles() as n:
        res = search.autosearch(model.loss, (params, batch),
                                search.loss_degradation, 48, threshold=5e-3)
    assert n[0] <= 2, f"search compiled {n[0]} executables"
    assert res.converged, res.table()
    assert res.evals_used <= 48
    assert len(res.policy().rules) >= 1


# --------------------------------------------------------------------------
# interpreter matcher fast path (satellite): policies that cannot match
# anything must not pay per-equation matcher calls, and repeated triples
# must hit the precompiled-matcher memo
# --------------------------------------------------------------------------

def test_empty_policy_skips_matcher_entirely():
    args = _toy_args()
    empty = TruncationPolicy(rules=())
    tr = truncate(_toy, empty, cache=False)
    before = policy_mod.MATCHER_EVALS
    tr(*args)
    assert policy_mod.MATCHER_EVALS == before, \
        "empty policy paid per-equation matcher calls"


def test_matcher_memo_evaluates_each_triple_once():
    pol = TruncationPolicy.scoped("mlp", "e5m2")
    before = policy_mod.MATCHER_EVALS
    r1 = pol.rule_for("mlp/dot", "dot_general", np.dtype("float32"))
    mid = policy_mod.MATCHER_EVALS
    r2 = pol.rule_for("mlp/dot", "dot_general", np.dtype("float32"))
    assert mid == before + 1
    assert policy_mod.MATCHER_EVALS == mid  # memo hit, no re-evaluation
    assert r1 is r2 is pol.rules[0]


def test_matcher_memo_bounded_by_distinct_triples():
    """Re-walking the same jaxpr (cache=False forces per-call walks) must
    not re-run the matcher: the policy memo serves every repeat triple."""
    args = _toy_args()
    pol = TruncationPolicy.everywhere("e5m2")
    tr = truncate(_toy, pol, cache=False)
    tr(*args)
    after_first = policy_mod.MATCHER_EVALS
    tr(*args)
    assert policy_mod.MATCHER_EVALS == after_first
