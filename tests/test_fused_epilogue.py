"""Bit-exactness of the fused quantize epilogue and its interpreter routing.

The producing kernels (flash_attention, rwkv6) take an optional (4,) int32
runtime format row and apply the dynamic quantize on their output stores
(``quantize_em.ref.quantize_epilogue``). The contract everything downstream
leans on: a fused kernel is bit-for-bit the unfused kernel composed with
``quantize_dynamic`` on the same row — for every search-ladder rung, both
overflow conventions, the armed fault channel, and the identity row — on
the Pallas interpret path (the kernel body as TPU would run it) and on the
compiled XLA dispatch path. The interpreter's table/policy transform relies
on this to route a site's row into the epilogue instead of appending a
separate quantize pass (``kernels/fused.py``)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  — import order: core before kernels
from repro.core import truncate, TruncationPolicy
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.fused import fused_outputs
from repro.kernels.quantize_em.ops import (
    quantize_dynamic, format_row, IDENTITY_ROW,
)
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ops import wkv6

# every ladder rung the precision search walks, both fp8 overflow
# conventions, a fault-armed row (bit 31 = sign flip, packed as
# field3 = ieee_inf | (bit+1) << 1), and the identity row (exact
# passthrough: fused kernels always run with the epilogue wired in)
ROWS = [
    ("e8m15", [8, 15, 0, 1]),
    ("e8m10", [8, 10, 0, 1]),
    ("e8m7", [8, 7, 0, 1]),
    ("e8m5", [8, 5, 0, 1]),
    ("e8m3", [8, 3, 0, 1]),
    ("e8m2", [8, 2, 0, 1]),
    ("e5m2", [5, 2, 0, 1]),
    ("e4m3s", [4, 3, 1, 0]),
    ("e4m3fn", [4, 3, 0, 0]),
    ("e4m3fn+fault31", [4, 3, 0, 64]),
    ("identity", list(IDENTITY_ROW)),
]
ROW_IDS = [n for n, _ in ROWS]
ROW_VALS = [np.array(r, np.int32) for _, r in ROWS]


def _bits(x):
    return np.asarray(jax.device_get(x)).view(np.uint32)


def _flash_args(seed=0):
    r = np.random.RandomState(seed)
    q = jnp.asarray(r.randn(1, 2, 128, 32) * 4, jnp.float32)
    k = jnp.asarray(r.randn(1, 2, 128, 32) * 4, jnp.float32)
    v = jnp.asarray(r.randn(1, 2, 128, 32) * 4, jnp.float32)
    return q, k, v


def _wkv_args(seed=0):
    r = np.random.RandomState(seed)
    B, H, S, hd = 1, 2, 64, 16
    rr, kk, vv = (jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
                  for _ in range(3))
    w = jnp.asarray(1 / (1 + np.exp(-r.randn(B, H, S, hd))), jnp.float32)
    u = jnp.asarray(r.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    return rr, kk, vv, w, u, s0


@pytest.mark.parametrize("row", ROW_VALS, ids=ROW_IDS)
def test_flash_fused_interpret_bit_exact(row):
    """Pallas kernel body (interpret mode): fused epilogue == unfused
    kernel composed with the ref dynamic quantize, bit for bit."""
    q, k, v = _flash_args()
    fused = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True,
                                   out_fmt=jnp.asarray(row))
    plain = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
    want = quantize_dynamic(plain, row, impl="ref")
    np.testing.assert_array_equal(_bits(fused), _bits(want))


@pytest.mark.parametrize("row", ROW_VALS, ids=ROW_IDS)
def test_wkv6_fused_interpret_bit_exact(row):
    rr, kk, vv, w, u, s0 = _wkv_args()
    y_f, sT_f = wkv6_pallas(rr, kk, vv, w, u, s0, chunk=32, interpret=True,
                            out_fmt=jnp.asarray(row))
    y, sT = wkv6_pallas(rr, kk, vv, w, u, s0, chunk=32, interpret=True)
    want = quantize_dynamic(y, row, impl="ref")
    np.testing.assert_array_equal(_bits(y_f), _bits(want))
    # the recurrence state is NOT covered by the epilogue (an ordinary
    # site for the interpreter) and must be untouched by the row
    np.testing.assert_array_equal(_bits(sT_f), _bits(sT))


@pytest.mark.parametrize("row", ROW_VALS, ids=ROW_IDS)
def test_flash_fused_compiled_bit_exact(row):
    """Compiled dispatch path: one jitted executable carrying the epilogue
    vs the unfused kernel + a separate quantize dispatch."""
    q, k, v = _flash_args(1)
    fused = jax.jit(lambda a, b, c, fr: flash_attention(
        a, b, c, causal=True, out_fmt=fr))(q, k, v, jnp.asarray(row))
    plain = jax.jit(lambda a, b, c: flash_attention(
        a, b, c, causal=True))(q, k, v)
    want = jax.jit(lambda y, fr: quantize_dynamic(y, fr, impl="ref"))(
        plain, jnp.asarray(row))
    np.testing.assert_array_equal(_bits(fused), _bits(want))


@pytest.mark.parametrize("row", ROW_VALS, ids=ROW_IDS)
def test_wkv6_fused_compiled_bit_exact(row):
    rr, kk, vv, w, u, s0 = _wkv_args(1)
    fused = jax.jit(lambda fr: wkv6(rr, kk, vv, w, u, s0,
                                    out_fmt=fr)[0])(jnp.asarray(row))
    plain = jax.jit(lambda: wkv6(rr, kk, vv, w, u, s0)[0])()
    want = quantize_dynamic(plain, row, impl="ref")
    np.testing.assert_array_equal(_bits(fused), _bits(want))


def test_fused_recognition_and_policy_routing():
    """The interpreter recognizes an epilogue-bearing pallas_call and routes
    a policy rule's format row into it; the routed result is bit-identical
    to quantizing the unfused kernel output with the same rule."""
    q, k, v = _flash_args(2)

    def fn(q, k, v):
        return flash_attention_pallas(
            q, k, v, causal=True, block_q=64, block_k=64, interpret=True,
            out_fmt=jnp.asarray(IDENTITY_ROW))

    def pallas_eqns(jx):
        out = []
        for e in jx.eqns:
            if e.primitive.name == "pallas_call":
                out.append(e)
            for p in e.params.values():
                if hasattr(p, "jaxpr"):
                    out += pallas_eqns(p.jaxpr)
        return out

    eqns = pallas_eqns(jax.make_jaxpr(fn)(q, k, v).jaxpr)
    assert len(eqns) == 1
    assert fused_outputs(eqns[0]) == (0,)

    pol = TruncationPolicy.everywhere("e8m3")
    routed = truncate(fn, pol, impl="interpret")(q, k, v)
    plain = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
    want = quantize_dynamic(plain, format_row("e8m3"), impl="ref")
    np.testing.assert_array_equal(_bits(routed), _bits(want))


def test_native_fp8_truncate_matches_emulated():
    """``truncate(..., native_fp8=True)`` executes quantize_dot_inputs
    sites on fp8 storage; for finite operands the pre-rounding is the bit
    oracle's, so the result matches the emulated path to f32 dot accuracy
    (identical operand values, possibly different accumulation order)."""
    from repro.core import TruncationRule, E4M3

    r = np.random.RandomState(3)
    a = jnp.asarray(r.randn(64, 32), jnp.float32)
    b = jnp.asarray(r.randn(32, 48), jnp.float32)
    rule = TruncationRule(fmt=E4M3, scope="*", ops=("dot_general",),
                          quantize_dot_inputs=True)
    pol = TruncationPolicy(rules=(rule,))

    def f(a, b):
        return a @ b

    emu = truncate(f, pol, impl="ref")(a, b)
    nat = truncate(f, pol, impl="ref", native_fp8=True)(a, b)
    np.testing.assert_allclose(np.asarray(nat), np.asarray(emu),
                               rtol=1e-6, atol=1e-5)
