"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED config (same family/topology, small
dims), runs one forward/train step on CPU, asserts output shapes and no
NaNs, and checks decode parity: token-by-token decode logits must match the
full parallel forward (catches cache/rope/state bugs — the strongest cheap
correctness signal for sequence models).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, ARCH_IDS
from repro.models import Model

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.input_mode == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        if cfg.rope_type == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
        batch.pop("tokens")
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return batch


# heavyweight archs run in the slow (full/CI) tier; the default tier-1 run
# keeps one dense and one MoE representative (see pytest.ini)
_FAST_ARCHS = {"h2o-danube-1.8b", "olmoe-1b-7b"}
_ARCH_PARAMS = [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


@pytest.mark.parametrize("arch_id", _ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id, "smoke")
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)

    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss))
    gn = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch_id", _ARCH_PARAMS)
def test_smoke_decode_parity(arch_id):
    """Greedy decode logits at each position == parallel forward logits."""
    cfg = get_config(arch_id, "smoke")
    model = Model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(1))
    T = 8
    batch = make_batch(cfg, rng)
    if cfg.input_mode == "embeds":
        small = {"embeds": batch["embeds"][:, :T]}
        if "positions" in batch:
            small["positions"] = batch["positions"][:, :, :T]
    elif cfg.family == "encdec":
        small = {"src_embeds": batch["src_embeds"][:, :T],
                 "tokens": batch["tokens"][:, :T]}
    else:
        small = {"tokens": batch["tokens"][:, :T]}
    full_logits = jax.jit(model.forward)(params, small)  # (B, T, V)

    cache = model.init_cache(B, T + 1)
    if cfg.family == "encdec":
        # cross-kv must be populated from the encoder for parity
        from repro.models import encdec as ed
        memory = ed.encode(params, small["src_embeds"], cfg)
        hd = cfg.resolved_head_dim
        ck, cv = [], []
        for i in range(cfg.n_layers):
            p_l = jax.tree_util.tree_map(lambda t: t[i],
                                         params["dec_layers"])
            k = (memory @ p_l["cross_attn"]["wk"]).reshape(
                B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
            v = (memory @ p_l["cross_attn"]["wv"]).reshape(
                B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
            ck.append(k)
            cv.append(v)
        cache = ed.init_cache(cfg, B, T + 1, memory_len=T)
        cache["cross_k"] = jnp.stack(ck)
        cache["cross_v"] = jnp.stack(cv)

    step = jax.jit(model.decode_step)
    maxdiff = 0.0
    for t in range(T):
        if cfg.input_mode == "embeds":
            logits, cache = step(params, cache,
                                 jnp.zeros((B,), jnp.int32),
                                 embeds=small["embeds"][:, t:t + 1])
        else:
            logits, cache = step(params, cache, small["tokens"][:, t])
        maxdiff = max(maxdiff,
                      float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    assert maxdiff < 2e-2, f"{arch_id}: decode/parallel mismatch {maxdiff}"


def test_ring_cache_wraps_correctly():
    """Decode past the sliding window: ring cache (window-sized) must match
    a full-length cache with window masking, token by token."""
    cfg = get_config("h2o-danube-1.8b", "smoke").replace(sliding_window=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    T = 12  # 3x the window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

    # reference: full parallel forward (window masks inside flash)
    full_logits = jax.jit(model.forward)(params, {"tokens": toks})

    cache = model.init_cache(B, T + 1)
    # ring allocated: cache seq dim == window
    assert cache["layers"]["k"].shape[3] == 4
    step = jax.jit(model.decode_step)
    maxdiff = 0.0
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t])
        maxdiff = max(maxdiff,
                      float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    assert maxdiff < 2e-2, maxdiff


def test_hymba_ring_plus_global_caches():
    """Hymba: ring caches for SWA layers, full caches for global layers."""
    cfg = get_config("hymba-1.5b", "smoke").replace(sliding_window=4)
    model = Model(cfg)
    cache = model.init_cache(2, 17)
    assert cache["layers"]["kv"]["k"].shape[3] == 4      # ring (SWA)
    assert cache["global"][0]["kv"]["k"].shape[2] == 17  # full (global)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    T = 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full_logits = jax.jit(model.forward)(params, {"tokens": toks})
    step = jax.jit(model.decode_step)
    cache = model.init_cache(B, T + 1)
    maxdiff = 0.0
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t])
        maxdiff = max(maxdiff,
                      float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    assert maxdiff < 2e-2, maxdiff
