"""Op-mode interpreter: HOP coverage, scoping, policies, grad composition."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    truncate, profile_counts, TruncationPolicy, TruncationRule,
    E5M2, BF16, magnitude_below, scope,
)
from repro.kernels.quantize_em.ops import quantize


def quant(x, fmt=E5M2):
    return quantize(jnp.asarray(x, jnp.float32), fmt, impl="ref")


def test_identity_policy_is_exact():
    def f(x):
        return jnp.sum(jnp.sin(x * 3) ** 2)
    x = jnp.asarray(np.random.RandomState(0).randn(16), jnp.float32)
    pol = TruncationPolicy.everywhere("fp32")
    assert float(truncate(f, pol)(x)) == float(f(x))


def test_single_op_semantics():
    """One multiply: truncate(f) == quantize(f) exactly."""
    def f(a, b):
        return a * b
    a = jnp.float32(1.234567)
    b = jnp.float32(7.654321)
    pol = TruncationPolicy.everywhere(E5M2)
    got = truncate(f, pol)(a, b)
    want = quant(a * b)
    assert float(got) == float(want)


def test_chained_op_semantics():
    """Each intermediate is rounded: ((a*b)_q + c)_q."""
    def f(a, b, c):
        return a * b + c
    a, b, c = map(jnp.float32, (1.7, 2.9, 0.111))
    pol = TruncationPolicy.everywhere(E5M2)
    got = truncate(f, pol)(a, b, c)
    want = quant(quant(a * b) + c)
    assert float(got) == float(want)


def test_scope_matching_through_scan():
    def f(x):
        with scope("inner"):
            def body(c, _):
                return jnp.sin(c * 1.01), None
            y, _ = lax.scan(body, x, None, length=4)
        return jnp.sum(y)
    x = jnp.asarray(np.random.RandomState(1).randn(8), jnp.float32)
    full = float(f(x))
    hit = float(truncate(f, TruncationPolicy.scoped("inner", E5M2))(x))
    miss = float(truncate(f, TruncationPolicy.scoped("elsewhere", E5M2))(x))
    assert hit != full
    assert miss == full


def test_while_and_cond():
    def f(x):
        y = lax.while_loop(lambda v: jnp.sum(v) < 100.0,
                           lambda v: v * 1.5 + 0.01, x)
        return lax.cond(jnp.sum(y) > 50, lambda a: a * 2.0,
                        lambda a: a / 2.0, y).sum()
    x = jnp.ones((4,), jnp.float32)
    full = float(f(x))
    tr = float(truncate(f, TruncationPolicy.everywhere(E5M2))(x))
    assert np.isfinite(tr) and tr != full


def test_remat_preserved():
    def f(x):
        return jnp.sum(jax.checkpoint(lambda v: jnp.tanh(v * 3))(x) ** 2)
    x = jnp.asarray(np.random.RandomState(2).randn(8), jnp.float32)
    pol = TruncationPolicy.everywhere(E5M2)
    tr = truncate(f, pol)
    v = float(tr(x))
    g = jax.grad(lambda v_: truncate(f, pol)(v_))(x)
    assert np.isfinite(v) and bool(jnp.all(jnp.isfinite(g)))


def test_custom_jvp_primal():
    @jax.custom_jvp
    def h(x):
        return jnp.sin(x)
    h.defjvp(lambda p, t: (jnp.sin(p[0]), jnp.cos(p[0]) * t[0]))

    def f(x):
        return jnp.sum(h(x * 2))
    x = jnp.asarray(np.random.RandomState(3).randn(8), jnp.float32)
    tr = float(truncate(f, TruncationPolicy.everywhere(E5M2))(x))
    assert np.isfinite(tr) and tr != float(f(x))


def test_grad_then_truncate_covers_backward():
    def loss(w):
        return jnp.sum(jnp.tanh(w) ** 2)
    w = jnp.asarray(np.random.RandomState(4).randn(16), jnp.float32)
    g_full = jax.grad(loss)(w)
    g_tr = truncate(jax.grad(loss), TruncationPolicy.everywhere(E5M2))(w)
    assert not np.allclose(np.asarray(g_full), np.asarray(g_tr))
    # every surviving value lies on the e5m2 grid
    q = quantize(g_tr, E5M2, impl="ref")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(g_tr))


def test_exclusion_fences_region():
    def f(x):
        with scope("a"):
            y = x * 1.1
        with scope("b"):
            z = y * 1.1
        return jnp.sum(y + z)
    x = jnp.asarray(np.random.RandomState(5).randn(8), jnp.float32)
    pol = TruncationPolicy.everywhere(E5M2)
    fenced = float(truncate(f, pol.excluding("a", "b"))(x))
    # with a and b fenced, only the unscoped add + reduce_sum are truncated
    y = x * jnp.float32(1.1)
    z = y * jnp.float32(1.1)
    want = float(quant(jnp.sum(quant(y + z)).astype(jnp.float32)))
    assert fenced == want


def test_from_width_rule():
    def f(x32):
        return jnp.sum(x32 * 1.01)
    x = jnp.asarray(np.random.RandomState(6).randn(8), jnp.float32)
    pol = TruncationPolicy.from_flag("64_to_5_10")   # no f64 ops present
    assert float(truncate(f, pol)(x)) == float(f(x))
    pol32 = TruncationPolicy.from_flag("32_to_5_2")
    assert float(truncate(f, pol32)(x)) != float(f(x))


def test_dynamic_mask_truncation():
    """AMR analogue: truncate only small-magnitude elements."""
    def f(x):
        return x * 1.0000001
    x = jnp.asarray([1e-4, 100.0], jnp.float32)
    rule = TruncationRule(fmt=E5M2, mask=magnitude_below(1.0))
    pol = TruncationPolicy(rules=(rule,))
    y = np.asarray(truncate(f, pol)(x))
    raw = np.asarray(f(x))
    # large element untouched by the mask, small element on the e5m2 grid
    assert y[1] == raw[1]
    q = np.asarray(quant(raw[0]))
    assert y[0] == q and y[0] != raw[0]


def test_dot_input_quantization():
    a = jnp.asarray(np.random.RandomState(7).randn(8, 8), jnp.float32)
    b = jnp.asarray(np.random.RandomState(8).randn(8, 8), jnp.float32)

    def f(a, b):
        return jnp.sum(a @ b)
    rule_in = TruncationRule(fmt=BF16, quantize_dot_inputs=True)
    pol_in = TruncationPolicy(rules=(rule_in,))
    got = float(truncate(f, pol_in)(a, b))
    want = float(jnp.sum(quantize(a, BF16) @ quantize(b, BF16)))
    # the final reduce-sum is itself quantized too; compare via quantize
    assert abs(got - float(quant(jnp.float32(want), BF16))) < 1e-3


def test_counters_scan_multiplier():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = lax.scan(body, x, None, length=5)
        return y
    x = jnp.eye(8, dtype=jnp.float32)
    rep = profile_counts(f, TruncationPolicy.everywhere(E5M2))(x)
    # 5 iterations x (2 * 8^3) flops
    assert rep.total_flops == pytest.approx(5 * 2 * 8 ** 3)
    assert rep.truncated_fraction == pytest.approx(1.0)


def test_scoped_policy_survives_grad():
    """Backward-pass ops keep their forward scope after normalization
    (jvp()/transpose() wrappers must not break RAPTOR scoping)."""
    def loss(w, x):
        with scope("mlp"):
            h = jnp.tanh(x @ w)
        return jnp.sum(h ** 2)
    w = jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)
    x = jnp.asarray(np.random.RandomState(1).randn(4, 8), jnp.float32)
    g_full = jax.grad(loss)(w, x)
    g_tr = truncate(jax.grad(loss), TruncationPolicy.scoped("mlp", E5M2))(w, x)
    assert not np.allclose(np.asarray(g_full), np.asarray(g_tr))
    g_miss = truncate(jax.grad(loss),
                      TruncationPolicy.scoped("nothing", E5M2))(w, x)
    np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_miss),
                               rtol=1e-6)
