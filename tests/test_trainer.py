"""Trainer: loss goes down, grad-accum equivalence, compression, truncated
training (the paper's technique as a first-class training feature)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import TruncationPolicy
from repro.models import Model
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.trainer import TrainConfig, make_train_step, init_opt_state


def tiny_model():
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", remat=False)
    return Model(cfg)


def fixed_batch(model, B=4, S=16, seed=0):
    r = np.random.RandomState(seed)
    toks = r.randint(0, model.cfg.vocab, (B, S + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def test_loss_decreases():
    model = tiny_model()
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0))
    step_fn = jax.jit(make_train_step(model, tc))
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(model, params, tc)
    batch = fixed_batch(model)
    losses = []
    for i in range(30):
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_grad_accum_equivalence():
    """accum=4 on a 4x batch == accum=1 average-of-microbatch gradients."""
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(1))
    batch = fixed_batch(model, B=8)

    tc1 = TrainConfig(optimizer=AdamWConfig(lr=1e-3), grad_accum=1)
    tc4 = TrainConfig(optimizer=AdamWConfig(lr=1e-3), grad_accum=4)
    s1 = jax.jit(make_train_step(model, tc1))
    s4 = jax.jit(make_train_step(model, tc4))
    o1 = init_opt_state(model, params, tc1)
    o4 = init_opt_state(model, params, tc4)
    p1, _, m1 = s1(params, o1, batch, jnp.int32(0))
    p4, _, m4 = s4(params, o4, batch, jnp.int32(0))
    # losses: mean-over-batch == mean-of-microbatch-means (equal sizes)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


@pytest.mark.slow
def test_truncated_training_runs_and_hurts_at_4bit():
    """Paper Fig. 7 in miniature: a 4-bit-mantissa training step degrades
    the loss trajectory vs fp32; an e8m16 step tracks it closely."""
    model = tiny_model()
    batch = fixed_batch(model)

    def run(policy, steps=15):
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                         policy=policy, policy_impl="ref")
        step_fn = jax.jit(make_train_step(model, tc))
        params = model.init(jax.random.PRNGKey(2))
        opt = init_opt_state(model, params, tc)
        for i in range(steps):
            params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
        return float(m["loss"])

    full = run(None)
    fine = run(TruncationPolicy.everywhere("e8m16"))
    coarse = run(TruncationPolicy.everywhere("e8m4"))
    assert abs(fine - full) < abs(coarse - full) + 1e-6
    assert np.isfinite(coarse)


def test_grad_compression_error_feedback():
    model = tiny_model()
    batch = fixed_batch(model)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                     grad_compression="bf16")
    step_fn = jax.jit(make_train_step(model, tc))
    params = model.init(jax.random.PRNGKey(3))
    opt = init_opt_state(model, params, tc)
    assert "err" in opt
    losses = []
    for i in range(30):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7
    # error buffer actually carries residuals
    nz = jax.tree_util.tree_reduce(
        lambda a, e: a + int(jnp.sum(e != 0)), opt["err"], 0)
    assert nz > 0


def test_int8_compression_trains():
    model = tiny_model()
    batch = fixed_batch(model)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                     grad_compression="int8")
    step_fn = jax.jit(make_train_step(model, tc))
    params = model.init(jax.random.PRNGKey(4))
    opt = init_opt_state(model, params, tc)
    losses = []
    for i in range(30):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


def test_warmup_cosine_schedule():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=0.01)


def test_bf16_params_master_copy():
    cfg = ArchConfig(name="tiny16", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                     dtype="bfloat16", remat=False)
    model = Model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2))
    params = model.init(jax.random.PRNGKey(5))
    opt = init_opt_state(model, params, tc)
    masters = [m for m in jax.tree_util.tree_leaves(opt["master"])
               if m is not None]
    assert masters and all(m.dtype == jnp.float32 for m in masters)
    step_fn = jax.jit(make_train_step(model, tc))
    batch = fixed_batch(model)
    p2, o2, m = step_fn(params, opt, batch, jnp.int32(0))
    assert bool(jnp.isfinite(m["loss"]))
