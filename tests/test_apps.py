"""Mini-app protocol + profiling-stack integration (fast tier-1 slice).

Small app configurations so every transform stays cheap; the full-size
FP64-oracle acceptance runs in the conformance tier
(tests/conformance/test_apps_e2e.py).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import search
from repro.apps import (
    APPS, get_app, observable_error, HeatDiffusion, PoissonCG, SodShockTube,
    oracle,
)
from repro.core import (
    truncate, truncate_sweep, memtrace, profile_counts, TruncationPolicy,
)

SMALL = {
    "sod": dict(n_cells=32, t_end=0.04),
    "heat": dict(n=8, n_explicit=8, n_implicit=1, cg_iters=6),
    "poisson": dict(n=8, cg_iters=12),
}


def small_app(name):
    return get_app(name, **SMALL[name])


@pytest.mark.parametrize("name", sorted(APPS))
def test_protocol_surface(name):
    """init_state honors dtype; run_observables returns the documented dict;
    the self-metric is exactly zero; scopes are discoverable in the jaxpr."""
    app = small_app(name)
    state = app.init_state(jnp.float32)
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == jnp.float32
    obs = app.run_observables(state)
    assert isinstance(obs, dict) and obs
    assert app.error_metric(obs, obs) == 0.0
    scopes = app.default_policy_scopes()
    assert scopes
    closed = jax.make_jaxpr(app.run_observables)(state)
    tree = search.scope_tree(closed)
    for s in scopes:
        assert any(path == s or path.startswith(s + "/") for path in tree), \
            (s, sorted(tree))


@pytest.mark.parametrize("name", sorted(APPS))
def test_init_state_f64_starts_from_f32_bits(name):
    """The oracle contract: f64 initial data carries exactly the f32-rounded
    values, so trajectories differ by solver arithmetic only."""
    app = small_app(name)
    from repro.compat import enable_x64
    s32 = app.init_state(jnp.float32)
    with enable_x64():
        s64 = app.init_state(jnp.float64)
        for a, b in zip(jax.tree_util.tree_leaves(s32),
                        jax.tree_util.tree_leaves(s64)):
            assert b.dtype == jnp.float64
            assert np.array_equal(np.asarray(a, np.float64), np.asarray(b))


@pytest.mark.parametrize("name", sorted(APPS))
def test_truncate_fine_format_stays_accurate(name):
    app = small_app(name)
    state = app.init_state(jnp.float32)
    obs = app.run_observables(state)
    lossy = truncate(app.run_observables, app.uniform_policy("e8m15"))(state)
    err = app.error_metric(obs, lossy)
    assert np.isfinite(err) and err < 1e-2, err


@pytest.mark.parametrize("name", sorted(APPS))
def test_truncate_sweep_matches_truncate(name):
    """The runtime-table sweep path equals per-policy truncate bit-for-bit
    on each app (the small-config slice of the conformance parity test)."""
    app = small_app(name)
    state = app.init_state(jnp.float32)
    sites = TruncationPolicy(rules=tuple(
        search.driver.TruncationRule(fmt=search.driver.FPFormat(8, 0),
                                     scope=s)
        for s in app.default_policy_scopes()))
    handle = truncate_sweep(app.run_observables, sites)(state)
    pol = app.uniform_policy("e8m5")
    swept = handle(handle.table(pol))
    direct = truncate(app.run_observables, pol)(state)
    for a, b in zip(jax.tree_util.tree_leaves(swept),
                    jax.tree_util.tree_leaves(direct)):
        an = np.asarray(jax.device_get(a))
        bn = np.asarray(jax.device_get(b))
        assert np.array_equal(an.view(np.uint32), bn.view(np.uint32))


def test_memtrace_flags_on_sod():
    app = small_app("sod")
    state = app.init_state(jnp.float32)
    _out, rep = memtrace(app.run_observables, app.uniform_policy("e8m2"),
                         threshold=1e-3)(state)
    assert int(np.sum(np.asarray(jax.device_get(rep.flags)))) > 0
    assert "hydro" in " ".join(rep.locations)


def test_profile_counts_on_heat():
    app = small_app("heat")
    state = app.init_state(jnp.float32)
    rep = profile_counts(app.run_observables, app.uniform_policy())(state)
    # the solver scopes carry truncated work; the harness (observables)
    # must not be matched by the scoped policy
    assert 0.0 < rep.truncated_fraction < 1.0


def test_autosearch_smoke_on_poisson():
    """A tiny-budget search on the smallest app converges and round-trips
    through the public truncate API."""
    app = small_app("poisson")
    state = app.init_state(jnp.float32)
    res = search.autosearch(app.run_observables, (state,),
                            metric=app.error_metric, budget=16,
                            threshold=5e-2)
    assert res.converged, res.table()
    obs = truncate(app.run_observables, res.policy())(state)
    assert app.error_metric(app.run_observables(state), obs) <= 5e-2


def test_oracle_verdict_small_heat():
    """FP64 reference wiring: the plain f32 run must pass its budget with a
    tiny floor, and the verdict renders."""
    app = small_app("heat")
    ref = oracle.fp64_reference(app)
    assert all(v.dtype == np.float64 for v in ref.values())
    v = oracle.verdict(app, oracle.fp32_observables(app), ref)
    assert v.passed and v.error == v.floor
    assert "PASS" in str(v)


def test_observable_error_edges():
    a = {"x": jnp.float32(2.0), "f": jnp.ones((4,), jnp.float32)}
    assert observable_error(a, a) == 0.0
    bad = {"x": jnp.float32(jnp.nan), "f": jnp.ones((4,), jnp.float32)}
    assert observable_error(a, bad) == float("inf")
    with pytest.raises(ValueError):
        observable_error(a, {"x": jnp.float32(1.0)})


def test_get_app_registry():
    assert sorted(APPS) == ["heat", "poisson", "sod"]
    assert isinstance(get_app("sod", n_cells=16), SodShockTube)
    assert isinstance(get_app("heat"), HeatDiffusion)
    assert isinstance(get_app("poisson"), PoissonCG)
    with pytest.raises(ValueError):
        get_app("navier-stokes")


def test_metric_resolution():
    """Satellite contract: autosearch metrics resolve from None (historical
    max_rel), names, and callables; from_observables lifts state-space
    outputs to observable space."""
    assert search.resolve_metric(None) is search.rel_error
    assert search.resolve_metric("max_rel") is search.rel_error
    assert search.resolve_metric("rel_l2") is search.rel_l2_error
    fn = lambda r, c: 0.123  # noqa: E731
    assert search.resolve_metric(fn) is fn
    with pytest.raises(ValueError):
        search.resolve_metric("nope")
    with pytest.raises(TypeError):
        search.resolve_metric(42)

    lifted = search.from_observables(lambda out: {"m": jnp.sum(out)},
                                     "rel")
    a = jnp.asarray([1.0, 2.0], jnp.float32)
    assert lifted(a, a) == 0.0
    assert lifted(a, a * 2) == pytest.approx(1.0)


def test_rel_l2_error_metric():
    r = np.asarray([3.0, 4.0], np.float32)
    assert search.rel_l2_error(r, r) == 0.0
    assert search.rel_l2_error(r, np.asarray([3.0, 5.0], np.float32)) == \
        pytest.approx(1.0 / 5.0)
    assert search.rel_l2_error(r, np.asarray([np.nan, 4.0], np.float32)) \
        == float("inf")
